"""Fig. 8: LLM-scale dissemination stress test — FLTorrent (full
unlinkability hardening) vs BitTorrent-only round time over
datacenter-class 7-10 Gbps links.

Paper overheads: Gemma-7B +9.97%, DeepSeek-R1-14B +6.60%,
Qwen2.5-32B +7.09%, Llama-3.3-70B +10.01% (i.e. ~6-10%).

Measured on the **event engine** (``time_engine="event"``,
:mod:`repro.net`): round times are wall-clock seconds — fair-share
flow makespans plus the tracker control plane (directive RTT +
per-cycle assignment solve, the real coordination cost at 10^4-10^5
pieces) — not slot counts.  The slot engine's quantized numbers are
reported alongside for contrast: it charges warm-up and BT stages the
same flat Δ, so the coordination overhead the paper measures is
invisible there (overhead ~ -0.3%).

Artifacts are bf16 checkpoints; BitTorrent piece size is 4 MiB (the
usual choice for multi-GB payloads; the paper's 256 KiB pieces at 51 MB
scale would yield ~10^5 pieces per update here).
"""
from __future__ import annotations

from repro.core import SwarmConfig, simulate_round
from repro.core.capacities import DATACENTER
from repro.net import DATACENTER_NET

from .common import banner, save

# update bytes = 2 bytes/param (bf16)
MODELS = {
    "Gemma-7B": 7e9 * 2,
    "DeepSeek-R1-14B": 14e9 * 2,
    "Qwen2.5-32B": 32e9 * 2,
    "Llama-3.3-70B": 70e9 * 2,
}

CHUNK = 4 * 2**20                      # 4 MiB pieces


def run(n: int = 50, fast: bool = False, net=DATACENTER_NET):
    """n peers on the paper's standard m=10 overlay; datacenter links.
    (A complete small cluster hides warm-up inefficiency entirely —
    coordination overhead needs a sparse overlay to show up.)"""
    banner("Fig. 8 — LLM-scale overhead vs BitTorrent-only (7-10 Gbps)")
    models = dict(MODELS)
    if fast:
        n = 24
        models = dict(list(models.items())[:2])
    rows = {}
    m = min(n - 1, 10)
    for name, nbytes in models.items():
        K = int(-(-nbytes // CHUNK))
        base_cfg = SwarmConfig(
            n=n, chunks_per_update=K, chunk_bytes=CHUNK, s_max=10**7,
            seed=0, min_degree=m, enable_gating=False,
            enable_preround=False, enable_timelag=False,
            enable_nonowner_first=False, warmup_threshold_pct=0.0)
        full_cfg = SwarmConfig(
            n=n, chunks_per_update=K, chunk_bytes=CHUNK, s_max=10**7,
            seed=0, min_degree=m)
        base = simulate_round(base_cfg, link_model=DATACENTER,
                              bt_mode="fluid", time_engine="event",
                              net=net).metrics
        full = simulate_round(full_cfg, link_model=DATACENTER,
                              bt_mode="fluid", time_engine="event",
                              net=net).metrics
        ovh = (full.t_round_s - base.t_round_s) / base.t_round_s
        slot_ovh = (full.t_round - base.t_round) / base.t_round
        rows[name] = {
            "chunks": K,
            "bt_only_s": round(base.t_round_s, 1),
            "fltorrent_s": round(full.t_round_s, 1),
            "overhead_pct": round(100 * ovh, 2),
            "warmup_share": round(full.warmup_share_s, 4),
            "control_s": round(full.control_s, 1),
            "spray_s": round(full.t_spray_s, 1),
            "slot_overhead_pct": round(100 * slot_ovh, 2),
        }
        print(f"{name:18s} K={K:6d} BT-only={base.t_round_s:8.1f}s "
              f"FLTorrent={full.t_round_s:8.1f}s overhead={ovh:+.2%} "
              f"(warm share {full.warmup_share_s:.1%}, "
              f"slot-engine ovh {slot_ovh:+.2%})")
    vals = [r["overhead_pct"] for r in rows.values()]
    in_band = all(4.0 <= v <= 12.0 for v in vals)
    print(f"\n(paper: +6% .. +10%; measured "
          f"{min(vals):+.2f}% .. {max(vals):+.2f}%, "
          f"{'IN' if in_band else 'OUT OF'} band)")
    save("fig8_llm_scale", {"n": n, "chunk_bytes": CHUNK,
                            "time_engine": "event",
                            "tracker_rtt_s": net.tracker_rtt_s,
                            "tracker_solve_s": net.tracker_solve_s,
                            "overhead_band_ok": in_band, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
