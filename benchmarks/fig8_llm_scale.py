"""Fig. 8: LLM-scale dissemination stress test — FLTorrent (full
unlinkability hardening) vs BitTorrent-only round time over
datacenter-class 7-10 Gbps links.

Paper overheads: Gemma-7B +9.97%, DeepSeek-R1-14B +6.60%,
Qwen2.5-32B +7.09%, Llama-3.3-70B +10.01% (i.e. ~6-10%).

Artifacts are bf16 checkpoints; BitTorrent piece size is 4 MiB (the
usual choice for multi-GB payloads; the paper's 256 KiB pieces at 51 MB
scale would yield ~10^5 pieces per update here).
"""
from __future__ import annotations

from repro.core import SwarmConfig, simulate_round
from repro.core.capacities import DATACENTER

from .common import banner, save

# update bytes = 2 bytes/param (bf16)
MODELS = {
    "Gemma-7B": 7e9 * 2,
    "DeepSeek-R1-14B": 14e9 * 2,
    "Qwen2.5-32B": 32e9 * 2,
    "Llama-3.3-70B": 70e9 * 2,
}

CHUNK = 4 * 2**20                      # 4 MiB pieces


def run(n: int = 50, fast: bool = False):
    """n peers on the paper's standard m=10 overlay; datacenter links.
    (A complete small cluster hides warm-up inefficiency entirely —
    coordination overhead needs a sparse overlay to show up.)"""
    banner("Fig. 8 — LLM-scale overhead vs BitTorrent-only (7-10 Gbps)")
    models = dict(MODELS)
    if fast:
        n = 24
        models = dict(list(models.items())[:2])
    rows = {}
    m = min(n - 1, 10)
    for name, nbytes in models.items():
        K = int(-(-nbytes // CHUNK))
        base_cfg = SwarmConfig(
            n=n, chunks_per_update=K, chunk_bytes=CHUNK, s_max=10**7,
            seed=0, min_degree=m, enable_gating=False,
            enable_preround=False, enable_timelag=False,
            enable_nonowner_first=False, warmup_threshold_pct=0.0)
        full_cfg = SwarmConfig(
            n=n, chunks_per_update=K, chunk_bytes=CHUNK, s_max=10**7,
            seed=0, min_degree=m)
        base = simulate_round(base_cfg, link_model=DATACENTER,
                              bt_mode="fluid").metrics
        full = simulate_round(full_cfg, link_model=DATACENTER,
                              bt_mode="fluid").metrics
        ovh = (full.t_round - base.t_round) / base.t_round
        rows[name] = {"chunks": K, "bt_only_s": int(base.t_round),
                      "fltorrent_s": int(full.t_round),
                      "overhead_pct": round(100 * ovh, 2)}
        print(f"{name:18s} K={K:6d} BT-only={base.t_round:6d}s "
              f"FLTorrent={full.t_round:6d}s overhead={ovh:+.2%}")
    print("\n(paper: +6% .. +10%)")
    save("fig8_llm_scale", {"n": n, "chunk_bytes": CHUNK, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
