"""Fig. 4: end-to-end round-time decomposition under privacy ablations
(Base = BitTorrent-only, K, K+PR, Full = K+PR+TL), 100 nodes, GoogLeNet
update (206 x 256 KiB).  Paper: Full warm-up 243.3 s, BT 1721.8 s,
total 1965.1 s -> ~3.9% total overhead vs Base 1891.8 s."""
from __future__ import annotations

from repro.core import SwarmConfig, simulate_round

from .common import banner, save

ABLATIONS = {
    "Base(BT-only)": dict(enable_gating=False, enable_preround=False,
                          enable_timelag=False,
                          enable_nonowner_first=False,
                          warmup_threshold_pct=0.0),
    "K": dict(enable_preround=False, enable_timelag=False),
    "K+PR": dict(enable_timelag=False),
    "Full(K+PR+TL)": dict(),
}


def run(n: int = 100, K: int = 206, fast: bool = False):
    banner("Fig. 4 — round decomposition under privacy ablations")
    if fast:
        n, K = 100, 206
    rows = {}
    base_total = None
    for name, kw in ABLATIONS.items():
        cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=100_000,
                          seed=0, **kw)
        res = simulate_round(cfg, bt_mode="fluid")
        m = res.metrics
        rows[name] = {"t_warm": int(m.t_warm),
                      "t_bt": int(m.t_round - m.t_warm),
                      "t_round": int(m.t_round),
                      "warm_share": round(m.warmup_share, 4)}
        if name.startswith("Base"):
            base_total = m.t_round
        print(f"{name:16s} warm={m.t_warm:6d}s bt={m.t_round - m.t_warm:6d}s "
              f"total={m.t_round:6d}s share={m.warmup_share:.3f}")
    full = rows["Full(K+PR+TL)"]["t_round"]
    overhead = (full - base_total) / base_total
    print(f"\nFull vs Base total overhead: {overhead:+.1%} "
          f"(paper: ~+3.9%)")
    save("fig4_decomposition", {"n": n, "K": K, "rows": rows,
                                "overhead_vs_base": overhead})
    return rows


if __name__ == "__main__":
    run()
