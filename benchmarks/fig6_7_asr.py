"""Figs. 6-7: privacy evaluation — ASR under the three observation-only
attacks across defense ablations, overlay density m, pre-round volume R,
network size n, and collusion size a.

Paper reference points (100 nodes, m=10): no-defense ASR near-perfect;
full defenses approach 1/m; m 5->25 drops max ASR 26.99%->4.29%;
R 10%->50% changes max ASR only 11.43%->11.27%; collusion a 5->25
raises any-success 13.56%->30.82% with per-attacker ASR 11.3-14.3%."""
from __future__ import annotations

import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.attacks import random_guess_baseline, run_all_attacks

from .common import banner, save


def _run_asr(n, K, observers, seeds=(0, 1), pooled=False, **kw):
    out = {"sequence": [], "count": [], "cluster": [], "any": []}
    for seed in seeds:
        # Attack figures pin the reference loop engine: its sequential
        # receiver processing (early receivers drain full downlink,
        # exhausting their non-owner unions into the owner fallback) is
        # the warm-up traffic shape the paper's no-defense ASR
        # baselines assume.  The batched engine round-robins receivers,
        # which *lowers* undefended ASR (fairer mixing) — fine for
        # throughput studies, wrong for reproducing Figs. 6-7 bars.
        cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=50_000,
                          seed=seed, scheduler_impl="loop", **kw)
        res = simulate_round(cfg, bt_mode="fluid")
        obs = np.arange(observers)
        # res.log is the typed TransferTrace; the vectorized scorers
        # consume it natively (bit-exact vs the historical dict path —
        # pinned in tests/golden_schedules.json).
        reps = run_all_attacks(res.log, obs, K, pooled=pooled)
        for k in ("sequence", "count", "cluster"):
            out[k].append(reps[k].max_asr)
        out["any"].append(max(r.any_correct_rate for r in reps.values()))
    return {k: float(np.mean(v)) for k, v in out.items()}


def run(n: int = 60, K: int = 64, fast: bool = False):
    banner("Figs. 6-7 — ASR ablations / density / volume / collusion")
    if fast:
        n, K = 30, 32
    obs = max(n // 10, 3)
    results = {}

    # --- Fig. 6: defense ablation ---
    ablations = {
        "none": dict(enable_preround=False, enable_timelag=False,
                     enable_gating=False, enable_nonowner_first=False),
        "PR only": dict(enable_timelag=False, enable_gating=False,
                        enable_nonowner_first=False),
        "TL only": dict(enable_preround=False, enable_gating=False,
                        enable_nonowner_first=False),
        "K only": dict(enable_preround=False, enable_timelag=False),
        "Full": dict(),
    }
    print(f"defense ablation (m=10, 1/m guess = "
          f"{random_guess_baseline(10):.2f}):")
    results["ablation"] = {}
    for name, kw in ablations.items():
        r = _run_asr(n, K, obs, **kw)
        results["ablation"][name] = r
        print(f"  {name:8s} seq={r['sequence']:.3f} count={r['count']:.3f}"
              f" cluster={r['cluster']:.3f}")

    # --- Fig. 7a: overlay density ---
    print("overlay density sweep (max ASR, Full defenses):")
    results["density"] = {}
    for m in (5, 10, 15, 25):
        if m >= n // 2:
            continue
        r = _run_asr(n, K, obs, min_degree=m)
        mx = max(r["sequence"], r["count"], r["cluster"])
        results["density"][m] = {**r, "max": mx,
                                 "guess": random_guess_baseline(m)}
        print(f"  m={m:3d}: max-ASR={mx:.3f} (1/m={1/m:.3f})")

    # --- Fig. 7b: pre-round volume (diminishing returns) ---
    print("pre-round volume sweep R:")
    results["volume"] = {}
    for R in (0.1, 0.2, 0.5):
        r = _run_asr(n, K, obs, spray_ratio=R)
        mx = max(r["sequence"], r["count"], r["cluster"])
        results["volume"][R] = mx
        print(f"  R={R:.1f}: max-ASR={mx:.3f}")

    # --- Fig. 7c: collusion ---
    print("collusion sweep (pooled observers a):")
    results["collusion"] = {}
    for a in (3, max(n // 8, 4), max(n // 4, 6)):
        r = _run_asr(n, K, a, pooled=True)
        mx = max(r["sequence"], r["count"], r["cluster"])
        results["collusion"][a] = {"per_attacker_max": mx,
                                   "any_success": r["any"]}
        print(f"  a={a:3d}: per-attack max-ASR={mx:.3f} "
              f"any-success={r['any']:.2f}")

    save("fig6_7_asr", {"n": n, "K": K, "results": results})
    return results


if __name__ == "__main__":
    run()
