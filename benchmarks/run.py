"""Benchmark harness entry point: one benchmark per paper table/figure
plus the §Roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run            # fast defaults
    PYTHONPATH=src python -m benchmarks.run --full     # paper scale
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale parameters (slow)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig3,table3")
    args = ap.parse_args(argv)
    fast = not args.full

    from . import (bench_analysis, bench_async, bench_attacks,
                   bench_net, bench_session, fig3_utilization,
                   fig4_decomposition, fig5_threshold, fig6_7_asr,
                   fig8_llm_scale, roofline, table2_learning,
                   table3_scaling)

    suite = {
        "analysis": lambda: bench_analysis.run(fast=fast),
        "table2": lambda: table2_learning.run(fast=fast),
        "async": lambda: bench_async.run(fast=fast),
        "session": lambda: bench_session.run(fast=fast),
        "attacks": lambda: bench_attacks.run(fast=fast),
        "net": lambda: bench_net.run(fast=fast),
        "fig3": lambda: fig3_utilization.run(fast=fast),
        "fig4": lambda: fig4_decomposition.run(fast=fast),
        "fig5": lambda: fig5_threshold.run(fast=fast),
        "table3": lambda: table3_scaling.run(
            fast=fast, sizes=(100, 200) if fast else (100, 200, 300)),
        "fig6_7": lambda: fig6_7_asr.run(fast=fast),
        "fig8": lambda: fig8_llm_scale.run(fast=fast),
        "roofline": lambda: roofline.run(fast=fast),
    }
    only = [s for s in args.only.split(",") if s]
    t0 = time.time()
    failures = []
    for name, fn in suite.items():
        if only and name not in only:
            continue
        try:
            fn()
        except Exception as e:                       # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"\n=== benchmarks done in {time.time() - t0:.0f}s; "
          f"{len(failures)} failures ===")
    for name, err in failures:
        print(f"  FAILED {name}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
