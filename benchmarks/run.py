"""Benchmark harness entry point: one benchmark per paper table/figure
plus the §Roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run            # fast defaults
    PYTHONPATH=src python -m benchmarks.run --full     # paper scale
    PYTHONPATH=src python -m benchmarks.run --check    # regression gate

``--check`` snapshots the committed ``results/bench/BENCH_*.json``
baselines BEFORE running, re-runs the selected suites, and diffs the
fresh payloads against the snapshots under the per-suite tolerances in
``CHECKS`` (see ``common.compare_bench``) — exit non-zero on any
regression.
"""
from __future__ import annotations

import argparse
import sys
import time

# Per-suite regression tolerances for --check.  Directions state which
# way is BETTER: "lower" wall clocks may not rise past the slack,
# "higher" capability counts may not fall, "equal" contracts (exit
# codes, validity booleans) may not move at all.
CHECKS = {
    "analysis": ("BENCH_analysis", [
        {"path": "smoke_exit_code", "direction": "equal"},
        {"path": "smoke_clean", "direction": "equal"},
        {"path": "files_analyzed", "direction": "higher"},
        {"path": "jit_targets_ready", "direction": "higher"},
        {"path": "cli_wall_s", "direction": "lower", "rel": 2.0,
         "abs": 5.0},
        {"path": "analyze_wall_s", "direction": "lower", "rel": 2.0,
         "abs": 5.0},
    ]),
    "obs": ("BENCH_obs", [
        {"path": "export_valid", "direction": "equal"},
        {"path": "perfetto_valid", "direction": "equal"},
        {"path": "tracks_covered", "direction": "equal"},
        {"path": "report_matches_metrics", "direction": "equal"},
        {"path": "control_s_matches", "direction": "equal"},
        {"path": "overhead_frac", "direction": "lower", "abs": 0.02},
        {"path": "rows", "direction": "higher", "rel": 0.5},
        {"path": "trace_events", "direction": "higher", "rel": 0.5},
        {"path": "record_wall_s", "direction": "lower", "rel": 3.0,
         "abs": 10.0},
    ]),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale parameters (slow)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig3,table3")
    ap.add_argument("--check", action="store_true",
                    help="diff fresh results against the committed "
                         "results/bench baselines; exit non-zero on "
                         "regression")
    args = ap.parse_args(argv)
    fast = not args.full

    from . import (bench_analysis, bench_async, bench_attacks,
                   bench_net, bench_obs, bench_session,
                   fig3_utilization, fig4_decomposition, fig5_threshold,
                   fig6_7_asr, fig8_llm_scale, roofline,
                   table2_learning, table3_scaling)
    from .common import compare_bench, load

    suite = {
        "analysis": lambda: bench_analysis.run(fast=fast),
        "obs": lambda: bench_obs.run(fast=fast),
        "table2": lambda: table2_learning.run(fast=fast),
        "async": lambda: bench_async.run(fast=fast),
        "session": lambda: bench_session.run(fast=fast),
        "attacks": lambda: bench_attacks.run(fast=fast),
        "net": lambda: bench_net.run(fast=fast),
        "fig3": lambda: fig3_utilization.run(fast=fast),
        "fig4": lambda: fig4_decomposition.run(fast=fast),
        "fig5": lambda: fig5_threshold.run(fast=fast),
        "table3": lambda: table3_scaling.run(
            fast=fast, sizes=(100, 200) if fast else (100, 200, 300)),
        "fig6_7": lambda: fig6_7_asr.run(fast=fast),
        "fig8": lambda: fig8_llm_scale.run(fast=fast),
        "roofline": lambda: roofline.run(fast=fast),
    }
    only = [s for s in args.only.split(",") if s]
    # Snapshot the committed baselines BEFORE running: the suites
    # overwrite their own results/bench artifacts as they go.
    baselines = {}
    if args.check:
        for name, (artifact, _specs) in CHECKS.items():
            if only and name not in only:
                continue
            baselines[name] = load(artifact)
    t0 = time.time()
    failures = []
    payloads = {}
    for name, fn in suite.items():
        if only and name not in only:
            continue
        try:
            payloads[name] = fn()
        except Exception as e:                       # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    if args.check:
        for name, (artifact, specs) in CHECKS.items():
            if only and name not in only:
                continue
            base, cur = baselines.get(name), payloads.get(name)
            if base is None:
                failures.append(
                    (name, f"no committed baseline {artifact}.json"))
                continue
            if not isinstance(cur, dict):
                continue                # suite already failed above
            diff = compare_bench(base, cur, specs)
            n_ok = sum(1 for c in diff["checked"] if c["ok"])
            print(f"\n--check {name}: {n_ok}/{len(diff['checked'])} "
                  f"metrics within tolerance of {artifact}.json")
            for r in diff["regressions"]:
                print(f"  REGRESSION {r['path']}: baseline "
                      f"{r['baseline']} -> current {r['current']}")
            for p in diff["unmatched"]:
                print(f"  MISSING baseline metric: {p}")
            if not diff["ok"]:
                failures.append((name, "regression gate"))
    print(f"\n=== benchmarks done in {time.time() - t0:.0f}s; "
          f"{len(failures)} failures ===")
    for name, err in failures:
        print(f"  FAILED {name}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
