"""Table III: end-to-end round cost scaling, 100-500 peers, Full
privacy, GreedyFastestFirst, 51 MB model @ 256 KiB chunks.

Paper: warm-up share stays ~11.5-12.4%, utilization 75-80%."""
from __future__ import annotations

from repro.core import SwarmConfig, simulate_round

from .common import Timer, banner, save


def run(sizes=(100, 200, 300), fast: bool = False, K: int = 206):
    banner("Table III — scaling 100-500 peers (Full privacy)")
    if fast:
        sizes, K = (50, 100), 64
    rows = {}
    print(f"{'n':>5s} {'T_warm(s)':>10s} {'Share%':>8s} {'Util%':>7s} "
          f"{'T_round(s)':>11s} {'wall(s)':>8s}")
    for n in sizes:
        cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=200_000, seed=0,
                          cand_cap=16384 if n > 150 else 0)
        with Timer() as t:
            res = simulate_round(cfg, bt_mode="fluid")
        m = res.metrics
        rows[n] = {"t_warm": int(m.t_warm),
                   "share_pct": round(100 * m.warmup_share, 1),
                   "util_pct": round(100 * m.warmup_utilization, 1),
                   "t_round": int(m.t_round)}
        print(f"{n:5d} {m.t_warm:10d} {100 * m.warmup_share:8.1f} "
              f"{100 * m.warmup_utilization:7.1f} {m.t_round:11d} "
              f"{t.seconds:8.1f}")
    shares = [r["share_pct"] for r in rows.values()]
    print(f"\nwarm-up share span: {min(shares):.1f}%..{max(shares):.1f}% "
          f"(paper: 11.5%..12.4%)")
    save("table3_scaling", {"K": K, "rows": rows})
    return rows


if __name__ == "__main__":
    import sys
    big = "--big" in sys.argv
    run(sizes=(100, 200, 300, 400, 500) if big else (100, 200, 300))
