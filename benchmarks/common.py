"""Shared benchmark utilities: result printing, JSON artifacts, and the
regression gate (``compare_bench``) behind ``run.py --check``."""
from __future__ import annotations

import fnmatch
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "bench")


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def load(name: str) -> dict | None:
    """Read a committed ``results/bench/<name>.json`` baseline."""
    path = os.path.join(RESULTS_DIR, name + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _flatten(d: dict, prefix: str = "") -> dict:
    """Nested dict -> dotted scalar paths (numbers and bools only)."""
    out: dict = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, key + "."))
        elif isinstance(v, bool) or isinstance(v, (int, float)):
            out[key] = v
    return out


def compare_bench(baseline: dict, current: dict,
                  tolerances: list[dict]) -> dict:
    """Diff a fresh benchmark payload against a committed baseline.

    ``tolerances`` is a list of specs, each::

        {"path": "cli_wall_s",          # fnmatch glob over dotted paths
         "direction": "lower",          # "lower" | "higher" | "equal"
         "rel": 0.5, "abs": 0.5}        # allowed slack (max of the two)

    ``direction`` states which way is BETTER for the metric: a
    ``"lower"`` metric (wall seconds) regresses when the current value
    exceeds baseline + slack; ``"higher"`` (accuracy, ready counts)
    when it falls below baseline - slack; ``"equal"`` (exact contracts
    like exit codes and validity booleans) when it leaves the slack
    band entirely.  A spec whose glob matches nothing in the baseline
    fails the gate — a silently-vanished metric is itself a regression.
    """
    base, cur = _flatten(baseline), _flatten(current)
    checked: list[dict] = []
    regressions: list[dict] = []
    unmatched: list[str] = []
    for spec in tolerances:
        paths = fnmatch.filter(sorted(base), spec["path"])
        if not paths:
            unmatched.append(spec["path"])
            continue
        direction = spec.get("direction", "equal")
        for p in paths:
            if p not in cur:
                regressions.append({"path": p, "baseline": base[p],
                                    "current": None,
                                    "reason": "missing in current run"})
                continue
            b, c = float(base[p]), float(cur[p])
            slack = max(abs(b) * spec.get("rel", 0.0),
                        spec.get("abs", 0.0))
            if direction == "lower":
                ok = c <= b + slack
            elif direction == "higher":
                ok = c >= b - slack
            else:
                ok = abs(c - b) <= slack
            entry = {"path": p, "baseline": base[p], "current": cur[p],
                     "direction": direction, "slack": slack, "ok": ok}
            checked.append(entry)
            if not ok:
                regressions.append(entry)
    return {"ok": not regressions and not unmatched,
            "checked": checked, "regressions": regressions,
            "unmatched": unmatched}


def banner(title: str):
    print("\n" + "=" * 72)
    print(title)
    print("=" * 72, flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
