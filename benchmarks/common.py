"""Shared benchmark utilities: result printing + JSON artifacts."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "bench")


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def banner(title: str):
    print("\n" + "=" * 72)
    print(title)
    print("=" * 72, flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
