"""repro.net benchmark: wall-clock round anatomy on the event engine.

Three claims, measured honestly in seconds (not slots):

* **warm-up share** — the paper's "warm-up is a stable ~12% share of a
  round" at paper scale (K=206, 256 KiB chunks, residential links)
  across n in {100, 200, 500};
* **LLM-scale overhead** — FLTorrent vs BT-only on 7-10 Gbps links
  lands in the paper's ~6-10% band (the fig8 measurement, one model
  here as the regression anchor);
* **time-domain bandwidth efficiency** — realized warm-up transport
  seconds vs the per-cycle congestion lower bound
  (:func:`repro.core.maxflow.warmup_time_bounds`), the seconds-domain
  companion of the ~92%-of-max-flow claim.

Plus the cross-validation anchor: the event engine must reproduce the
slot engine's per-cycle transfer counts exactly (same schedules, real
clock).

    python benchmarks/bench_net.py [--quick]

Emits ``results/bench/BENCH_net.json``.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

from common import banner, save  # noqa: E402
from repro.core import SwarmConfig  # noqa: E402
from repro.core.capacities import DATACENTER, RESIDENTIAL  # noqa: E402
from repro.core.maxflow import warmup_time_bounds  # noqa: E402
from repro.core.simulator import RoundSimulator  # noqa: E402
from repro.net import (DATACENTER_NET, RESIDENTIAL_NET,  # noqa: E402
                       NetConfig)

SHARE_BAND = (0.08, 0.16)        # ~12% +/- 4
OVERHEAD_BAND = (4.0, 12.0)      # ~6-10%, with measurement slack


def _event_round(cfg, link_model, net, **kw):
    sim = RoundSimulator(cfg, link_model, time_engine="event", net=net,
                         **kw)
    return sim, sim.run()


def warm_share_sweep(ns, seed=0):
    rows = []
    for n in ns:
        cfg = SwarmConfig(n=n, chunks_per_update=206, s_max=50_000,
                          seed=seed)
        t0 = time.time()
        sim, res = _event_round(cfg, RESIDENTIAL, RESIDENTIAL_NET,
                                bt_mode="fluid")
        m = res.metrics
        rows.append({
            "n": n,
            "t_warm_s": round(m.t_warm_s, 1),
            "t_round_s": round(m.t_round_s, 1),
            "warmup_share_s": round(m.warmup_share_s, 4),
            "control_s": round(m.control_s, 1),
            "spray_s": round(m.t_spray_s, 1),
            "sim_seconds": round(time.time() - t0, 1),
        })
        print(f"n={n:4d}  t_warm={m.t_warm_s:7.1f}s "
              f"t_round={m.t_round_s:8.1f}s share={m.warmup_share_s:.1%} "
              f"(sim {rows[-1]['sim_seconds']:.0f}s)")
    return rows


def llm_overhead(n=50, model_bytes=7e9 * 2):
    chunk = 4 * 2**20
    K = int(-(-model_bytes // chunk))
    m = min(n - 1, 10)
    base_cfg = SwarmConfig(
        n=n, chunks_per_update=K, chunk_bytes=chunk, s_max=10**7,
        seed=0, min_degree=m, enable_gating=False, enable_preround=False,
        enable_timelag=False, enable_nonowner_first=False,
        warmup_threshold_pct=0.0)
    full_cfg = SwarmConfig(n=n, chunks_per_update=K, chunk_bytes=chunk,
                           s_max=10**7, seed=0, min_degree=m)
    _, b = _event_round(base_cfg, DATACENTER, DATACENTER_NET,
                        bt_mode="fluid")
    _, f = _event_round(full_cfg, DATACENTER, DATACENTER_NET,
                        bt_mode="fluid")
    ovh = 100 * (f.metrics.t_round_s - b.metrics.t_round_s) \
        / b.metrics.t_round_s
    print(f"LLM overhead (n={n}, K={K}): {ovh:+.2f}% "
          f"(BT {b.metrics.t_round_s:.0f}s -> FLT "
          f"{f.metrics.t_round_s:.0f}s)")
    return {"n": n, "chunks": K, "bt_only_s": round(b.metrics.t_round_s, 1),
            "fltorrent_s": round(f.metrics.t_round_s, 1),
            "overhead_pct": round(ovh, 2)}


def time_domain_efficiency(n=100, seed=0):
    """Realized warm-up transport seconds vs congestion lower bound."""
    cfg = SwarmConfig(n=n, chunks_per_update=206, s_max=50_000,
                      seed=seed)
    net = NetConfig()           # zero latency: realized is exact
    sim, res = _event_round(cfg, RESIDENTIAL, net, bt_mode="fluid")
    lbs, real = warmup_time_bounds(res.log, cfg.chunk_bytes,
                                   sim.up_bps, sim.down_bps)
    eff = float(lbs.sum() / max(real.sum(), 1e-12))
    print(f"time-domain efficiency (n={n}, GFF): {eff:.3f} "
          f"of the bandwidth-optimal bound")
    return {"n": n, "efficiency": round(eff, 4),
            "lb_s": round(float(lbs.sum()), 1),
            "realized_s": round(float(real.sum()), 1)}


def counts_parity(n=60, K=64, seed=0):
    """Event engine == slot engine, transfer for transfer."""
    cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=20_000, seed=seed)
    rs = RoundSimulator(cfg).run()
    re = RoundSimulator(cfg, time_engine="event",
                        net=NetConfig(tracker_rtt_s=0.0)).run()
    ok = (len(rs.log) == len(re.log)
          and bool(np.array_equal(rs.log.chunk, re.log.chunk))
          and bool(np.array_equal(rs.log.slot, re.log.slot)))
    print(f"slot/event schedule parity (n={n}, K={K}): "
          f"{'OK' if ok else 'BROKEN'}")
    return ok


def run(fast: bool = False):
    banner("BENCH repro.net — wall-clock rounds on the event engine")
    ns = (100, 200) if fast else (100, 200, 500)
    shares = warm_share_sweep(ns)
    share_ok = all(SHARE_BAND[0] <= r["warmup_share_s"] <= SHARE_BAND[1]
                   for r in shares)
    ovh = llm_overhead(n=24 if fast else 50)
    ovh_ok = OVERHEAD_BAND[0] <= ovh["overhead_pct"] <= OVERHEAD_BAND[1]
    eff = time_domain_efficiency(n=60 if fast else 100)
    parity = counts_parity()
    print(f"\nwarm-share band {'OK' if share_ok else 'VIOLATED'}; "
          f"overhead band {'OK' if ovh_ok else 'VIOLATED'}")
    payload = {
        "bench": "net",
        "date": time.strftime("%Y-%m-%d %H:%M:%S"),
        "warm_share": shares,
        "share_band": SHARE_BAND,
        "share_band_ok": share_ok,
        "llm_overhead": ovh,
        "overhead_band": OVERHEAD_BAND,
        "overhead_band_ok": ovh_ok,
        "time_domain": eff,
        "counts_parity_ok": parity,
    }
    save("BENCH_net", payload)
    return payload


if __name__ == "__main__":
    run(fast="--quick" in sys.argv)
