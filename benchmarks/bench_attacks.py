"""Attack-scorer vectorization benchmark -> BENCH_attacks.json.

Measures the three §IV-C attack scorers — vectorized grouped-statistics
implementations over the typed :class:`TransferTrace` vs the historical
per-observation dict-loop references — on warm-up traces from n=100..500
swarms, asserting decision-for-decision equality while timing both.

The paper's privacy sweeps (Figs. 6-7: ablations x density x volume x
collusion x seeds) re-score the same traces dozens of times, so scorer
cost is the sweep bottleneck once simulation is batched; the vectorized
path removes the Python loop over observations (hundreds of thousands
of events at n=500).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.attacks import ATTACKS, ATTACKS_REFERENCE

from .common import banner, save


def _time(fn, reps=3):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(fast: bool = False, sizes=None):
    banner("BENCH attacks — vectorized vs dict-loop ASR scoring")
    if sizes is None:
        sizes = (100, 200) if fast else (100, 300, 500)
    K = 16
    results = {}
    for n in sizes:
        cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=50_000, seed=0)
        res = simulate_round(cfg, bt_mode="fluid")
        obs = np.arange(max(n // 10, 3))
        warm_events = int((res.log.phase == 1).sum())
        row = {"events": len(res.log), "warmup_events": warm_events,
               "observers": int(obs.size), "attacks": {}}
        for name in ATTACKS:
            t_vec, r_vec = _time(lambda: ATTACKS[name](res.log, obs, K))
            t_ref, r_ref = _time(
                lambda: ATTACKS_REFERENCE[name](res.log, obs, K))
            assert r_vec.asr_per_observer == r_ref.asr_per_observer, name
            assert r_vec.n_decisions == r_ref.n_decisions, name
            row["attacks"][name] = {
                "t_vectorized_s": t_vec, "t_loop_s": t_ref,
                "speedup": t_ref / max(t_vec, 1e-12),
                "max_asr": r_vec.max_asr,
                "n_decisions": r_vec.n_decisions,
            }
        tot_vec = sum(a["t_vectorized_s"] for a in row["attacks"].values())
        tot_ref = sum(a["t_loop_s"] for a in row["attacks"].values())
        row["speedup_combined"] = tot_ref / max(tot_vec, 1e-12)
        results[n] = row
        print(f"  n={n}: {warm_events} warm-up events, combined speedup "
              f"{row['speedup_combined']:.1f}x "
              + " ".join(f"{a}={v['speedup']:.1f}x"
                         for a, v in row["attacks"].items()))
    save("BENCH_attacks", {"K": K, "sizes": list(sizes),
                           "results": results})
    return results


if __name__ == "__main__":
    run()
