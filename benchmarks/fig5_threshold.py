"""Fig. 5: warm-up duration as the threshold K grows (% of the swarm
chunk universe).  Paper: ~99.5 s @5%, ~238.8 s @10%, ~1084.7 s @50%."""
from __future__ import annotations

from repro.core import SwarmConfig, simulate_round

from .common import banner, save


def run(n: int = 100, K: int = 206, fast: bool = False,
        sweep=(0.05, 0.10, 0.25, 0.50)):
    banner("Fig. 5 — warm-up duration vs threshold K")
    if fast:
        n, K, sweep = 100, 206, (0.05, 0.10, 0.25)
    rows = {}
    prev = 0
    for pct in sweep:
        cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=100_000,
                          seed=0, warmup_threshold_pct=pct)
        res = simulate_round(cfg, bt_mode="fluid")
        t = int(res.metrics.t_warm)
        rows[f"{pct:.0%}"] = t
        mono = "OK" if t >= prev else "NON-MONOTONE!"
        print(f"K={pct:4.0%}: t_warm={t:6d}s  [{mono}]")
        prev = t
    save("fig5_threshold", {"n": n, "K": K, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
