"""Dist-collective microbenchmark: torrent ring vs one-shot all-reduce.

Measures, on an 8-fake-device host mesh (pod axis = 8):

1. ``torrent_fedavg`` wall time across ``n_blocks`` in {1, 2, 4, 8},
   plus the int8 wire-compression path, with the structural
   collective-permute count from the lowered HLO ((P-1) x n_blocks
   [+ P-1 scale sends when compressed] — the paper's chunked
   dissemination schedule made visible to the XLA scheduler).
2. The ``psum`` comparator: the same masked FedAvg as a single fused
   all-reduce (what a datacenter job would run) — the latency budget
   the chunked ring trades against for overlap and per-chunk
   compression.

Emits ``results/bench/BENCH_dist.json``.

Usage:  python benchmarks/bench_dist.py [--d ELEMS] [--reps N]
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import argparse      # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from common import banner, save  # noqa: E402
from repro.dist.torrent import masked_weights, torrent_fedavg  # noqa: E402
from repro.sharding.api import AxisType, make_mesh, shard_map  # noqa: E402

PODS = 8


def _time(fn, args, reps: int) -> float:
    out = fn(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_ring(mesh, ups, w, a, n_blocks: int, compress: bool, reps: int):
    fn = jax.jit(lambda u, ww, aa: torrent_fedavg(
        u, ww, aa, mesh=mesh, n_blocks=n_blocks, compress=compress))
    with mesh:
        txt = fn.lower(ups, w, a).as_text()
        dt = _time(fn, (ups, w, a), reps)
    n_cp = len(re.findall(r"collective.permute", txt))
    return {"n_blocks": n_blocks, "compress": compress,
            "ms": round(dt * 1e3, 3), "collective_permutes": n_cp}


def bench_psum(mesh, ups, w, a, reps: int):
    """Masked FedAvg as one fused all-reduce (the datacenter baseline)."""
    def body(x, wn):
        idx = jax.lax.axis_index("pod")
        return jax.lax.psum(x[0] * wn[idx], "pod")

    def agg(u, ww, aa):
        wn = masked_weights(ww, aa)
        return shard_map(body, mesh,
                         in_specs=(P("pod", None), P(None)),
                         out_specs=P(None), check_rep=False)(u["w"], wn)

    fn = jax.jit(agg)
    with mesh:
        txt = fn.lower(ups, w, a).as_text()
        dt = _time(fn, (ups, w, a), reps)
    n_ar = len(re.findall(r"all.reduce", txt))
    return {"ms": round(dt * 1e3, 3), "all_reduces": n_ar}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=1 << 20,
                    help="update elements per pod (default 1Mi = 4 MiB)")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()

    assert jax.device_count() >= PODS, jax.device_count()
    mesh = make_mesh((PODS, 1), ("pod", "data"),
                     axis_types=(AxisType.Auto,) * 2)
    key = jax.random.PRNGKey(0)
    ups = {"w": jax.random.normal(key, (PODS, args.d), jnp.float32)}
    w = jnp.arange(1.0, PODS + 1.0)
    a = jnp.ones(PODS)

    payload = {"bench": "dist", "pods": PODS, "d": args.d,
               "bytes_per_pod": args.d * 4,
               "date": time.strftime("%Y-%m-%d %H:%M:%S")}

    banner(f"torrent ring, P={PODS}, D={args.d} f32, n_blocks sweep")
    ring = []
    for nb in (1, 2, 4, 8):
        r = bench_ring(mesh, ups, w, a, nb, False, args.reps)
        print(f"  n_blocks={nb:2d}  {r['ms']:8.2f} ms  "
              f"{r['collective_permutes']} collective-permutes")
        ring.append(r)
    rc = bench_ring(mesh, ups, w, a, 4, True, args.reps)
    print(f"  n_blocks= 4  {rc['ms']:8.2f} ms  "
          f"{rc['collective_permutes']} collective-permutes  [int8 wire]")
    payload["ring"] = ring
    payload["ring_compressed"] = rc

    banner("psum all-reduce comparator")
    ps = bench_psum(mesh, ups, w, a, args.reps)
    print(f"  fused all-reduce  {ps['ms']:8.2f} ms")
    payload["psum"] = ps

    # structural acceptance: (P-1) x n_blocks explicit sends
    payload["schedule_ok"] = all(
        r["collective_permutes"] >= (PODS - 1) * r["n_blocks"]
        for r in ring)

    path = save("BENCH_dist", payload)
    print(f"\nwrote {path}")
    print(f"schedule_ok (>= (P-1)*n_blocks permutes): "
          f"{payload['schedule_ok']}")


if __name__ == "__main__":
    main()
