"""Slot-engine benchmark: loop vs batched vs jit (PR 8 acceptance).

Measures

1. **Headline speedup** — one full default round (spray -> warm-up ->
   exact BT) at the paper's n=100 / K=64 stress point, batched engine
   vs the per-receiver loop engine.
2. **Scaling sweep** — warm-up wall clock at n in {500, 1000, 2000,
   5000} with K=206 (GoogLeNet chunking) and a constant per-client
   warm-up goal (warmup_threshold_pct = 5/n, i.e. k_term = 1030
   chunks/client at every n), jit vs batched (vs loop at n=500).  The
   jit rows carry the engine's per-phase breakdown — bitplane build /
   matching / extraction on the engine side, spray / warm-up / trace
   emit on the simulator side — via the injected measurement clock.
3. **scaling_bends** — a log-log power-law fit of the batched curve,
   extrapolated to n=5000, must sit far above the jit engine's
   measured point: the packed-bitplane kernel visibly bends the
   scaling curve.

Emits ``results/bench/BENCH_scheduler.json``.

Usage:  python benchmarks/bench_scheduler.py [--quick] [--smoke]

``--quick`` stops the sweep at n=1000; ``--smoke`` runs only the
n=500 jit point under a generous wall-clock gate and exits non-zero
on a miss (the CI perf smoke).
"""
from __future__ import annotations

import argparse
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import banner, save  # noqa: E402
from repro.core import SwarmConfig, simulate_round  # noqa: E402
from repro.core import jit_engine  # noqa: E402
from repro.core import simulator as sim_mod  # noqa: E402

K_SWEEP = 206          # Table III / GoogLeNet chunk count
CAP_SWEEP = 8192       # stratified candidate cap (state.candidate_columns)


def _round(cfg: SwarmConfig, bt_mode: str = "auto"):
    t0 = time.time()
    res = simulate_round(cfg, bt_mode=bt_mode)
    dt = time.time() - t0
    m = res.metrics
    return dt, {
        "t_warm": m.t_warm,
        "t_round": m.t_round,
        "warmup_utilization": round(m.warmup_utilization, 4),
        "overall_utilization": round(m.overall_utilization, 4),
        "warmup_share": round(m.warmup_share, 4),
        "failed_open": m.failed_open,
    }


def headline(n: int = 100, k: int = 64, seed: int = 0, reps: int = 4):
    """Full exact-BT round, interleaved best-of-reps per engine.

    Interleaving the engines and taking per-engine minima makes the
    ratio robust to background load on shared boxes (single-run wall
    clock here swings ~±20%).
    """
    best = {"batched": None, "loop": None}
    met = {}
    for i in range(reps):
        for impl in ("batched", "loop"):
            if impl == "loop" and i >= max(2, reps - 2):
                continue               # loop is ~6x slower; 2 reps do
            cfg = SwarmConfig(n=n, chunks_per_update=k, s_max=100_000,
                              seed=seed, scheduler_impl=impl)
            dt, m = _round(cfg)
            if best[impl] is None or dt < best[impl]:
                best[impl], met[impl] = dt, m
    out = {}
    for impl in ("batched", "loop"):
        out[impl] = {"seconds": round(best[impl], 3), **met[impl]}
        print(f"  {impl:7s}: {best[impl]:6.2f}s  "
              f"t_warm={met[impl]['t_warm']} "
              f"t_round={met[impl]['t_round']} "
              f"util={met[impl]['warmup_utilization']}", flush=True)
    out["speedup"] = round(out["loop"]["seconds"]
                           / out["batched"]["seconds"], 2)
    print(f"  speedup: {out['speedup']}x", flush=True)
    return out


def _sweep_cfg(n: int, impl: str) -> SwarmConfig:
    # warmup_threshold_pct = 5/n keeps k_term at 1030 chunks per client
    # for every n, so sweep points differ only in swarm size.
    return SwarmConfig(n=n, chunks_per_update=K_SWEEP, s_max=100_000,
                       seed=0, scheduler="greedy_fastest_first",
                       scheduler_impl=impl,
                       warmup_threshold_pct=5.0 / n,
                       cand_cap=CAP_SWEEP)


def engine_point(n: int, impl: str) -> dict:
    """One warm-up-only sweep point with per-phase breakdown."""
    # measured_clock installs the perf clock into BOTH the simulator
    # and the jit engine and restores them even if the run raises —
    # the scoped replacement for the leaky set_clock(...)/set_clock(None)
    # pairing this harness used to hand-roll.
    with sim_mod.measured_clock() as clk:
        jit_engine.reset_phase_timers()
        t0 = clk()
        sim = sim_mod.RoundSimulator(_sweep_cfg(n, impl))
        setup_s = clk() - t0
        res = sim.run(warmup_only=True)
        total_s = clk() - t0
        engine_ph = jit_engine.reset_phase_timers()
    tm = res.timings
    m = res.metrics
    row = {
        "n": n, "K": K_SWEEP, "impl": impl, "cand_cap": CAP_SWEEP,
        "t_warm": m.t_warm,
        "failed_open": m.failed_open,
        "warmup_utilization": round(m.warmup_utilization, 4),
        "total_s": round(total_s, 2),
        # state alloc + overlay.  Setup and spray used to dominate the
        # large-n sweep points via quadratic python-loop fills (n=5000:
        # setup 30.5s, spray 2.7s); the vectorized fill/spray paths
        # hold them near-flat (~3s / ~1.2s at the same point), so the
        # sweep now times the engines, not the harness.
        "setup_s": round(setup_s, 2),
        "phases": {
            "spray_s": round(tm["spray_s"], 2),
            "warmup_s": round(tm["warmup_s"], 2),
            "trace_emit_s": round(tm["emit_s"], 2),
        },
    }
    if impl == "jit":
        # Engine-side split of warmup_s (host decode + rng + candidate
        # prep is the remainder).
        row["phases"].update(
            {k: round(v, 2) for k, v in engine_ph.items()})
    print(f"  n={n:5d} {impl:7s}: warm-up {tm['warmup_s']:7.2f}s  "
          f"(total {total_s:6.1f}s, setup {setup_s:4.1f}s, "
          f"t_warm={m.t_warm}, failed_open={m.failed_open})", flush=True)
    return row


def _fit_power(rows) -> tuple[float, float]:
    """Least-squares log-log fit warmup_s ~ a * n^p -> (a, p)."""
    xs = [math.log(r["n"]) for r in rows]
    ys = [math.log(max(r["phases"]["warmup_s"], 1e-9)) for r in rows]
    mx = sum(xs) / len(xs)
    my = sum(ys) / len(ys)
    vxx = sum((x - mx) ** 2 for x in xs)
    p = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / vxx
    a = math.exp(my - p * mx)
    return a, p


def scaling_sweep(sizes):
    """jit vs batched warm-up scaling; loop joins at the smallest n."""
    rows = []
    for n in sizes:
        rows.append(engine_point(n, "jit"))
        if n <= 2000:                  # batched at n=5000 takes ~an hour
            rows.append(engine_point(n, "batched"))
        if n == sizes[0]:
            rows.append(engine_point(n, "loop"))
    return rows


def bend_check(rows) -> dict:
    """Extrapolate the batched power law to the largest jit point."""
    batched = [r for r in rows if r["impl"] == "batched"]
    jit = [r for r in rows if r["impl"] == "jit"]
    if len(batched) < 2 or not jit:
        return {"scaling_bends": "insufficient points"}
    a, p = _fit_power(batched)
    top = max(jit, key=lambda r: r["n"])
    pred = a * top["n"] ** p
    meas = top["phases"]["warmup_s"]
    out = {
        "batched_fit_exponent": round(p, 2),
        "batched_extrapolated_s_at_n%d" % top["n"]: round(pred, 1),
        "jit_measured_s_at_n%d" % top["n"]: round(meas, 1),
        "bend_factor": round(pred / max(meas, 1e-9), 1),
        "scaling_bends": bool(meas < 0.5 * pred),
    }
    print(f"  batched ~ n^{p:.2f}; extrapolated to n={top['n']}: "
          f"{pred:.0f}s vs jit measured {meas:.1f}s "
          f"(bend x{out['bend_factor']}, bends={out['scaling_bends']})",
          flush=True)
    return out


def smoke(bound_s: float = 300.0) -> int:
    """CI perf gate: one warm-up-only jit round at n=500/K=206 must
    finish inside a generous wall-clock bound on a cold CPU."""
    banner(f"Smoke: n=500/K={K_SWEEP} jit warm-up under {bound_s:.0f}s")
    row = engine_point(500, "jit")
    ok = (not row["failed_open"]) and row["total_s"] <= bound_s
    print(f"  smoke {'OK' if ok else 'MISS'}: total {row['total_s']}s "
          f"(bound {bound_s:.0f}s), failed_open={row['failed_open']}")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="stop the scaling sweep at n=1000")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: only the n=500 jit point")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())

    payload = {"bench": "scheduler",
               "date": time.strftime("%Y-%m-%d %H:%M:%S")}

    banner("Headline: n=100/K=64 full round, batched vs loop")
    payload["headline_n100_k64"] = headline()

    banner("Warm-up scaling sweep: jit vs batched, K=206, k_term=1030")
    sizes = [500, 1000] if args.quick else [500, 1000, 2000, 5000]
    payload["scaling_sweep"] = scaling_sweep(sizes)
    payload.update(bend_check(payload["scaling_sweep"]))

    top_jit = [r for r in payload["scaling_sweep"]
               if r["impl"] == "jit" and r["n"] == 5000]
    payload["n5000_warmup_under_60s"] = (
        bool(top_jit and not top_jit[0]["failed_open"]
             and top_jit[0]["phases"]["warmup_s"] < 60.0)
        if top_jit else "skipped (--quick)")
    ok = payload["headline_n100_k64"]["speedup"] >= 5.0
    payload["speedup_target_met"] = ok

    path = save("BENCH_scheduler", payload)
    print(f"\nwrote {path}")
    print(f"speedup {payload['headline_n100_k64']['speedup']}x "
          f"(target >=5x: {'OK' if ok else 'MISS'}); "
          f"n=5000 jit warm-up < 60s: "
          f"{payload.get('n5000_warmup_under_60s')}")


if __name__ == "__main__":
    main()
