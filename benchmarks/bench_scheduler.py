"""Slot-engine microbenchmark: batched vs loop (tentpole acceptance).

Measures

1. **Headline speedup** — one full default round (spray -> warm-up ->
   exact BT) at the paper's n=100 / K=64 stress point, batched engine
   vs the per-receiver loop engine.
2. **Warm-up slots/sec** — batched-engine scheduler throughput at
   n in {50, 100, 200, 500} (fluid BT so only the scheduler under test
   is timed), including the Table III n=500 / K=206 configuration,
   which must complete its warm-up phase.

Emits ``results/bench/BENCH_scheduler.json``.

Usage:  python benchmarks/bench_scheduler.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import banner, save  # noqa: E402
from repro.core import SwarmConfig, simulate_round  # noqa: E402


def _round(cfg: SwarmConfig, bt_mode: str = "auto"):
    t0 = time.time()
    res = simulate_round(cfg, bt_mode=bt_mode)
    dt = time.time() - t0
    m = res.metrics
    return dt, {
        "t_warm": m.t_warm,
        "t_round": m.t_round,
        "warmup_utilization": round(m.warmup_utilization, 4),
        "overall_utilization": round(m.overall_utilization, 4),
        "warmup_share": round(m.warmup_share, 4),
        "failed_open": m.failed_open,
    }


def headline(n: int = 100, k: int = 64, seed: int = 0, reps: int = 4):
    """Full exact-BT round, interleaved best-of-reps per engine.

    Interleaving the engines and taking per-engine minima makes the
    ratio robust to background load on shared boxes (single-run wall
    clock here swings ~±20%).
    """
    best = {"batched": None, "loop": None}
    met = {}
    for i in range(reps):
        for impl in ("batched", "loop"):
            if impl == "loop" and i >= max(2, reps - 2):
                continue               # loop is ~6x slower; 2 reps do
            cfg = SwarmConfig(n=n, chunks_per_update=k, s_max=100_000,
                              seed=seed, scheduler_impl=impl)
            dt, m = _round(cfg)
            if best[impl] is None or dt < best[impl]:
                best[impl], met[impl] = dt, m
    out = {}
    for impl in ("batched", "loop"):
        out[impl] = {"seconds": round(best[impl], 3), **met[impl]}
        print(f"  {impl:7s}: {best[impl]:6.2f}s  "
              f"t_warm={met[impl]['t_warm']} "
              f"t_round={met[impl]['t_round']} "
              f"util={met[impl]['warmup_utilization']}", flush=True)
    out["speedup"] = round(out["loop"]["seconds"]
                           / out["batched"]["seconds"], 2)
    print(f"  speedup: {out['speedup']}x", flush=True)
    return out


def warm_throughput(sweep):
    """Batched warm-up slots/sec across swarm sizes (fluid BT)."""
    rows = []
    for n, k, cap in sweep:
        cfg = SwarmConfig(n=n, chunks_per_update=k, s_max=100_000,
                          seed=0, scheduler_impl="batched", cand_cap=cap)
        dt, m = _round(cfg, bt_mode="fluid")
        row = {"n": n, "K": k, "cand_cap": cap, "seconds": round(dt, 2),
               "warm_slots_per_sec": round(m["t_warm"] / max(dt, 1e-9), 1),
               **m}
        rows.append(row)
        print(f"  n={n:4d} K={k:3d} cap={cap}: t_warm={m['t_warm']} "
              f"util={m['warmup_utilization']} "
              f"{row['warm_slots_per_sec']} warm-slots/s "
              f"({dt:.1f}s, failed_open={m['failed_open']})", flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the n=500 Table III configuration")
    args = ap.parse_args()

    payload = {"bench": "scheduler",
               "date": time.strftime("%Y-%m-%d %H:%M:%S")}

    banner("Headline: n=100/K=64 full round, batched vs loop")
    payload["headline_n100_k64"] = headline()

    banner("Batched warm-up throughput sweep (fluid BT)")
    sweep = [(50, 64, 0), (100, 64, 0), (200, 64, 0)]
    if not args.quick:
        # Table III scale: n=500, K=206 (GoogLeNet chunking).  The
        # packed engine is ~linear in the candidate count, so capping
        # (cand_cap) no longer pays for itself — run exact.
        sweep.append((500, 206, 0))
    payload["warm_throughput"] = warm_throughput(sweep)

    n500 = [r for r in payload["warm_throughput"] if r["n"] == 500]
    payload["n500_warmup_completed"] = (
        bool(n500 and not n500[0]["failed_open"]) if n500
        else "skipped (--quick)")
    ok = payload["headline_n100_k64"]["speedup"] >= 5.0
    payload["speedup_target_met"] = ok

    path = save("BENCH_scheduler", payload)
    print(f"\nwrote {path}")
    print(f"speedup {payload['headline_n100_k64']['speedup']}x "
          f"(target >=5x: {'OK' if ok else 'MISS'}); "
          f"n500 warm-up completed: "
          f"{payload.get('n500_warmup_completed')}")


if __name__ == "__main__":
    main()
