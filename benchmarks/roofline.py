"""§Roofline report: aggregate the dry-run artifacts into the per-cell
three-term roofline table (compute / memory / collective seconds per
step, dominant term, MODEL_FLOPS/HLO ratio).

Reads results/dryrun_baseline/*.json (written by repro.launch.dryrun);
does NOT itself compile anything, so it runs on the 1-device container.
"""
from __future__ import annotations

import glob
import json
import os

from .common import banner, save

BASE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def load_records(dirname: str = "dryrun_baseline", mesh: str = "single"):
    recs = []
    for f in sorted(glob.glob(os.path.join(BASE, dirname,
                                           f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def run(dirname: str = "", fast: bool = False):
    if not dirname:
        # prefer the post-§Perf artifacts when present
        dirname = "dryrun_opt" if glob.glob(
            os.path.join(BASE, "dryrun_opt", "*.json")) \
            else "dryrun_baseline"
    banner(f"§Roofline — per-cell terms from {dirname} (single-pod)")
    recs = load_records(dirname)
    if not recs:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return {}
    print(f"{'arch':22s}{'shape':12s}{'GiB':>6s} {'t_comp':>9s} "
          f"{'t_mem':>9s} {'t_coll':>9s}  {'dominant':10s} "
          f"{'useful':>7s} {'mfu_bnd':>8s}")
    rows = {}
    for r in recs:
        t = r["roofline"]
        gb = r["memory"]["total_per_device_bytes"] / 2**30
        key = f"{r['arch']}__{r['shape']}"
        rows[key] = {
            "t_compute_s": t["t_compute_s"],
            "t_memory_s": t["t_memory_s"],
            "t_collective_s": t["t_collective_s"],
            "dominant": t["dominant"],
            "useful_flops_ratio": t.get("useful_flops_ratio"),
            "useful_mfu_bound": t.get("useful_mfu_bound"),
            "gib_per_device": gb,
            "fits_16g": gb <= 16.0,
        }
        print(f"{r['arch']:22s}{r['shape']:12s}{gb:6.1f} "
              f"{t['t_compute_s']:9.3f} {t['t_memory_s']:9.3f} "
              f"{t['t_collective_s']:9.3f}  {t['dominant']:10s} "
              f"{t.get('useful_flops_ratio', 0):7.2f} "
              f"{t.get('useful_mfu_bound', 0):8.3f}")
    n_fit = sum(1 for v in rows.values() if v["fits_16g"])
    print(f"\n{n_fit}/{len(rows)} cells fit 16 GiB/device")
    save("roofline_table", {"dirname": dirname, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
