"""SwarmSession microbenchmark: multi-round throughput under churn.

Measures

1. **Rounds/sec and warm-up share vs churn rate** at n in {100, 200}
   (K=64, fluid BT so the session layer + scheduler are what's timed):
   the persistent-population path must not get slower as churn rises —
   incremental edge repair touches O(churned peers), not O(n).
2. **Re-mesh latency** — ``ElasticFLStep`` cost of rebuilding mesh +
   ring schedule + jit when the active pod count changes (first call at
   a new P), vs the cached-revisit cost.

Emits ``results/bench/BENCH_session.json``.

Usage:  python benchmarks/bench_session.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

from common import banner, save  # noqa: E402
from repro.core import ChurnModel, SwarmConfig, SwarmSession  # noqa: E402


def churn_sweep(sizes, churn_rates, rounds: int):
    rows = []
    for n in sizes:
        for cr in churn_rates:
            cfg = SwarmConfig(n=n, chunks_per_update=64, s_max=100_000,
                              seed=0)
            ses = SwarmSession(cfg, churn=ChurnModel(
                leave_prob=cr, join_rate=cr * n / 4, rejoin_after=2),
                bt_mode="fluid")
            t0 = time.time()
            recs = ses.run(rounds)
            dt = time.time() - t0
            shares = [r.result.metrics.warmup_share for r in recs]
            row = {
                "n": n, "churn_rate": cr, "rounds": rounds,
                "seconds": round(dt, 2),
                "rounds_per_sec": round(rounds / max(dt, 1e-9), 3),
                "warmup_share_mean": round(float(np.mean(shares)), 4),
                "participation_mean": round(
                    float(ses.participation().mean()), 4),
                "edge_persistence": round(ses.edge_persistence(), 4),
                "failed_open_rounds": sum(
                    r.result.metrics.failed_open for r in recs),
            }
            rows.append(row)
            print(f"  n={n:4d} churn={cr:4.2f}: "
                  f"{row['rounds_per_sec']:6.2f} rounds/s  "
                  f"warm_share={row['warmup_share_mean']}  "
                  f"particip={row['participation_mean']}  "
                  f"persist={row['edge_persistence']}", flush=True)
    return rows


def remesh_latency():
    """ElasticFLStep rebuild cost per distinct pod count (trace + jit
    + first execution) vs a cached revisit."""
    import jax
    import jax.numpy as jnp

    from repro.dist.fl_step import ElasticFLStep
    from repro.models import ArchConfig, init_params
    from repro.optim import adamw_init
    from repro.optim.schedules import constant_lr

    cfg = ArchConfig(name="bench", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, head_dim=16,
                     d_ff=128, vocab=128, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = ElasticFLStep(cfg, lr_schedule=constant_lr(1e-3),
                         mesh_factory=lambda p: None)
    rng = np.random.default_rng(0)

    def batch(p):
        x = rng.integers(0, 128, size=(p, 2, 16))
        return {"inputs": jnp.asarray(x, jnp.int32),
                "labels": jnp.asarray(x, jnp.int32)}

    out = {}
    for label, p in (("build_p4", 4), ("remesh_p3", 3),
                     ("revisit_p4", 4)):
        t0 = time.perf_counter()
        params, opt, m = step(params, opt, batch(p), jnp.ones(p),
                              jnp.ones(p))
        jax.tree_util.tree_map(lambda x: x.block_until_ready(), m)
        out[label + "_ms"] = round((time.perf_counter() - t0) * 1e3, 2)
        print(f"  {label:11s} (P={p}): {out[label + '_ms']:8.1f} ms",
              flush=True)
    return out


def run(fast: bool = True):
    payload = {"bench": "session",
               "date": time.strftime("%Y-%m-%d %H:%M:%S")}

    banner("SwarmSession rounds/sec + warm-up share vs churn rate")
    sizes = (100,) if fast else (100, 200)
    churn_rates = (0.0, 0.1) if fast else (0.0, 0.05, 0.1, 0.2)
    payload["churn_sweep"] = churn_sweep(sizes, churn_rates,
                                         rounds=3 if fast else 5)

    banner("Elastic re-mesh latency (mesh + ring schedule + jit)")
    payload["remesh"] = remesh_latency()

    # Churn must not break warm-up liveness or throughput collapse.
    payload["no_failed_open"] = all(
        r["failed_open_rounds"] == 0 for r in payload["churn_sweep"])
    base = {r["n"]: r["rounds_per_sec"]
            for r in payload["churn_sweep"] if r["churn_rate"] == 0.0}
    payload["churn_slowdown_ok"] = all(
        r["rounds_per_sec"] >= 0.3 * base[r["n"]]
        for r in payload["churn_sweep"])

    path = save("BENCH_session", payload)
    print(f"\nwrote {path}")
    print(f"no_failed_open: {payload['no_failed_open']}; "
          f"churn_slowdown_ok (>=0.3x zero-churn): "
          f"{payload['churn_slowdown_ok']}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="n=100 only, fewer churn rates")
    args = ap.parse_args()
    run(fast=args.quick)


if __name__ == "__main__":
    main()
